//! Node-state storage bench (ISSUE 7): proves the lazy sparse store is
//! O(visited) — not O(n) — in memory and housekeeping, without moving a
//! single bit of the trace.
//!
//! Three legs:
//!
//! 1. **scale_1m dense vs lazy** (short horizon, so the visited set is a
//!    genuinely sparse fraction of the graph — at the preset's full
//!    1000-step horizon coupon-collecting visits nearly every node and
//!    the comparison would measure nothing). Before any clock or byte is
//!    trusted the leg **asserts `Trace::bit_identical`** between the two
//!    modes — z, the full event log, flags, and every θ̂ float at the
//!    bit level. A "memory win" that moved a bit is a bug, not a result.
//!    Acceptance bar: lazy resident state ≤ ½ the dense columns.
//! 2. **scale_10m no-regression report**: the 10⁷-node probe in both
//!    modes, steps/s side by side (report only — the win at 10⁷ is the
//!    ~1 GB of dense state that lazy never allocates).
//! 3. **scale_100m completion probe**: the 10⁸-node preset end-to-end in
//!    lazy mode — the run the dense columns priced out entirely (~10 GB
//!    before the first step). Asserts completion, visited ≪ n (hard:
//!    the visited count is deterministic), and resident state under the
//!    memory budget.
//!
//! Writes `BENCH_state.json` (or `$DECAFORK_BENCH_OUT`).
//!
//! Env knobs: `DECAFORK_STATE_N` shrinks leg 1's node count (CI smoke),
//! `DECAFORK_STATE_STEPS_1M` overrides leg 1's sparse horizon (default
//! 40), `DECAFORK_PERF_STEPS` rescales the 10m/100m probes,
//! `DECAFORK_PERF_SKIP_10M=1` / `DECAFORK_PERF_SKIP_100M=1` skip the
//! big probes (CI runners), `DECAFORK_STATE_MEM_BUDGET` sets the 100m
//! resident-byte budget (default 6 GiB), `DECAFORK_STATE_WORKERS` sets
//! the shard-worker count (default 7 workers = 8 shards), and
//! `DECAFORK_PERF_NO_ENFORCE=1` downgrades the memory bars to reports
//! (the bit-identical assert is **never** downgraded).

mod perf_common;

use decafork::scenario::{presets, GraphSpec, Scenario};
use decafork::walks::NodeStateMode;
use perf_common::{assert_bit_identical, enforce_bar, env_u64, write_bench_json};
use std::time::Instant;

struct Run {
    secs: f64,
    visited: usize,
    state_bytes: usize,
    trace: decafork::sim::metrics::Trace,
}

/// Build, run to the horizon, and measure one scenario/mode/shards cell.
fn run_cell(scenario: &Scenario, mode: NodeStateMode, shards: usize) -> anyhow::Result<Run> {
    let mut s = scenario.clone();
    s.params.node_state = mode;
    let mut e = s.sharded_engine(0, shards)?;
    let t0 = Instant::now();
    e.run_to(s.horizon);
    let secs = t0.elapsed().as_secs_f64();
    let visited = e.states().visited_count();
    let state_bytes = e.states().memory_bytes();
    Ok(Run { secs, visited, state_bytes, trace: e.into_trace() })
}

fn steps_per_sec(r: &Run) -> f64 {
    perf_common::steps_per_sec(&r.trace, r.secs)
}

fn main() -> anyhow::Result<()> {
    let workers =
        env_u64("DECAFORK_STATE_WORKERS").map(|w| (w as usize).max(1)).unwrap_or(7);
    let shards = workers + 1;

    // ---- Leg 1: dense vs lazy at scale_1m, sparse-regime horizon ----
    let mut m1 = presets::scale_1m();
    m1.params.record_theta = true; // θ̂ floats must match bit-for-bit too
    let n1 = env_u64("DECAFORK_STATE_N").map(|n| (n as usize).max(10_000)).unwrap_or(1_000_000);
    if n1 != 1_000_000 {
        m1.graph = GraphSpec::RandomRegular { n: n1, d: 8 };
    }
    m1.rescale_to(env_u64("DECAFORK_STATE_STEPS_1M").map(|s| s.max(10)).unwrap_or(40));
    println!("perf_state leg 1: {} | {} steps | {shards} shards", m1.label(), m1.horizon);

    let dense = run_cell(&m1, NodeStateMode::Dense, shards)?;
    let lazy = run_cell(&m1, NodeStateMode::Lazy, shards)?;

    // The oracle comes before the clock: identical bits or no result.
    assert_bit_identical(&dense.trace, &lazy.trace, "lazy store diverged from dense at scale_1m");
    assert!(
        lazy.visited < dense.visited,
        "lazy must materialize strictly fewer states than the dense column (got {} vs {})",
        lazy.visited,
        dense.visited
    );
    let visited_frac = lazy.visited as f64 / n1 as f64;
    let mem_ratio = lazy.state_bytes as f64 / dense.state_bytes as f64;
    println!("  dense state             : {:>12} B ({} states)", dense.state_bytes, dense.visited);
    println!(
        "  lazy state              : {:>12} B ({} states, {:.1}% of nodes visited)",
        lazy.state_bytes,
        lazy.visited,
        visited_frac * 100.0
    );
    println!("  lazy / dense memory     : {mem_ratio:>8.3}  (acceptance bar: <= 0.5)");
    println!(
        "  steps/s dense / lazy    : {:>8.1} / {:.1}",
        steps_per_sec(&dense),
        steps_per_sec(&lazy)
    );
    let leg1_pass = mem_ratio <= 0.5;

    // ---- Leg 2: scale_10m no-regression report (both modes) ----
    let skip_10m = std::env::var("DECAFORK_PERF_SKIP_10M").is_ok();
    let mut m10 = presets::scale_10m();
    if let Some(steps) = env_u64("DECAFORK_PERF_STEPS") {
        m10.rescale_to(steps.max(100));
    }
    let leg2 = if skip_10m {
        println!("\nscale_10m: skipped (DECAFORK_PERF_SKIP_10M)");
        None
    } else {
        println!("\nperf_state leg 2: {} | {} steps", m10.label(), m10.horizon);
        let d = run_cell(&m10, NodeStateMode::Dense, shards)?;
        let l = run_cell(&m10, NodeStateMode::Lazy, shards)?;
        assert!(
            d.trace.bit_identical(&l.trace),
            "lazy store diverged from dense at scale_10m"
        );
        anyhow::ensure!(!l.trace.extinct, "scale_10m went extinct before its horizon");
        let (sd, sl) = (steps_per_sec(&d), steps_per_sec(&l));
        println!("  steps/s dense / lazy    : {sd:>8.1} / {sl:.1} ({:.2}x)", sl / sd);
        println!(
            "  state bytes dense / lazy: {} / {} ({} of 10^7 nodes visited)",
            d.state_bytes, l.state_bytes, l.visited
        );
        Some((d, l))
    };

    // ---- Leg 3: scale_100m completion probe under a memory budget ----
    let skip_100m = std::env::var("DECAFORK_PERF_SKIP_100M").is_ok();
    let mem_budget =
        env_u64("DECAFORK_STATE_MEM_BUDGET").unwrap_or(6 * 1024 * 1024 * 1024) as usize;
    let mut m100 = presets::scale_100m();
    if let Some(steps) = env_u64("DECAFORK_PERF_STEPS") {
        m100.rescale_to(steps.max(50));
    }
    let leg3 = if skip_100m {
        println!("\nscale_100m: skipped (DECAFORK_PERF_SKIP_100M)");
        None
    } else {
        println!("\nperf_state leg 3: {} | {} steps", m100.label(), m100.horizon);
        let l = run_cell(&m100, NodeStateMode::Lazy, shards)?;
        anyhow::ensure!(!l.trace.extinct, "scale_100m went extinct before its horizon");
        let n = 100_000_000usize;
        // Deterministic: at most z·T ≪ n/4 nodes can ever be visited.
        assert!(
            l.visited < n / 4,
            "scale_100m visited {} of {n} nodes — the O(visited) premise failed",
            l.visited
        );
        println!(
            "  completed               : {:>8.1} steps/s, final z = {}",
            steps_per_sec(&l),
            l.trace.z.last().unwrap()
        );
        println!(
            "  resident state          : {:>12} B for {} visited nodes (budget {} B)",
            l.state_bytes, l.visited, mem_budget
        );
        Some(l)
    };
    let leg3_pass = leg3.as_ref().map(|l| l.state_bytes <= mem_budget).unwrap_or(true);

    let pass = leg1_pass && leg3_pass;
    let leg2_json = match &leg2 {
        None => "null".to_string(),
        Some((d, l)) => format!(
            "{{\n    \"steps\": {},\n    \"steps_per_sec_dense\": {:.1},\n    \"steps_per_sec_lazy\": {:.1},\n    \"state_bytes_dense\": {},\n    \"state_bytes_lazy\": {},\n    \"visited_lazy\": {}\n  }}",
            m10.horizon,
            steps_per_sec(d),
            steps_per_sec(l),
            d.state_bytes,
            l.state_bytes,
            l.visited
        ),
    };
    let leg3_json = match &leg3 {
        None => "null".to_string(),
        Some(l) => format!(
            "{{\n    \"steps\": {},\n    \"steps_per_sec\": {:.1},\n    \"state_bytes\": {},\n    \"visited\": {},\n    \"mem_budget_bytes\": {mem_budget},\n    \"under_budget\": {leg3_pass}\n  }}",
            m100.horizon,
            steps_per_sec(l),
            l.state_bytes,
            l.visited
        ),
    };
    let json = format!(
        "{{\n  \"bench\": \"perf_state\",\n  \"mode\": \"lazy sparse node store vs dense columns, traces asserted bit-identical\",\n  \"shards\": {shards},\n  \"scale_1m\": {{\n    \"n\": {n1},\n    \"steps\": {},\n    \"bit_identical\": true,\n    \"theta_samples_compared\": {},\n    \"state_bytes_dense\": {},\n    \"state_bytes_lazy\": {},\n    \"visited_lazy\": {},\n    \"visited_fraction\": {visited_frac:.4},\n    \"memory_ratio_lazy_over_dense\": {mem_ratio:.4},\n    \"steps_per_sec_dense\": {:.1},\n    \"steps_per_sec_lazy\": {:.1}\n  }},\n  \"scale_10m\": {leg2_json},\n  \"scale_100m\": {leg3_json},\n  \"acceptance_max_memory_ratio\": 0.5,\n  \"pass\": {pass}\n}}\n",
        m1.horizon,
        dense.trace.theta.len(),
        dense.state_bytes,
        lazy.state_bytes,
        lazy.visited,
        steps_per_sec(&dense),
        steps_per_sec(&lazy),
    );
    let out = write_bench_json("BENCH_state.json", &json)?;

    enforce_bar(
        pass,
        format!("perf_state memory bars not met (ratio {mem_ratio:.3} / budget) — see {out}"),
    )
}
