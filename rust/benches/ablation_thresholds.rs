//! Ablation: DECAFORK+ threshold pair (ε, ε₂) including the paper's
//! stated (3.25, 5.75) and design-rule-consistent choices from the
//! Irwin–Hall quantiles (`1 − F_{Σ_{Z0−1}}(ε₂ − ½) ≈ 0`). Quantifies the
//! churn (forks+terminations per run) each pair buys for its reaction
//! time — the inconsistency EXPERIMENTS.md documents.

use decafork::report::Table;
use decafork::sim::engine::SimParams;
use decafork::sim::{run_many, AggregateTrace, ControlSpec, ExperimentConfig, FailureSpec, GraphSpec};
use decafork::stats::irwin_hall::{design_epsilon, design_epsilon2};

fn main() -> anyhow::Result<()> {
    let runs: usize = std::env::var("DECAFORK_BENCH_RUNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    let designed_eps = design_epsilon(10, 1e-3);
    let designed_eps2 = design_epsilon2(10, 1e-3);
    println!(
        "design rule at delta=1e-3 for Z0=10: eps={designed_eps:.2} eps2={designed_eps2:.2} (paper uses 3.25/5.75)\n"
    );
    let mut table = Table::new(&[
        "(eps, eps2)",
        "mean Z (t>1k)",
        "std Z (t>1k)",
        "reaction b1",
        "forks/run",
        "terms/run",
        "extinct",
    ]);
    let mut arms = vec![
        ("paper (3.25, 5.75)".to_string(), 3.25, 5.75),
        (format!("designed ({designed_eps:.2}, {designed_eps2:.2})"), designed_eps, designed_eps2),
        ("tight terminate (3.25, 7.0)".to_string(), 3.25, 7.0),
        ("loose fork (2.0, 5.75)".to_string(), 2.0, 5.75),
    ];
    for (label, eps, eps2) in arms.drain(..) {
        let cfg = ExperimentConfig {
            graph: GraphSpec::RandomRegular { n: 100, d: 8 },
            params: SimParams {
                shards: decafork::scenario::parse::shards_from_env()?,
                ..Default::default()
            },
            control: ControlSpec::DecaforkPlus { epsilon: eps, epsilon2: eps2 },
            failures: FailureSpec::paper_bursts(),
            horizon: 10_000,
            runs,
            seed: 0xEB52,
        };
        let (traces, agg) = run_many(&cfg, 0)?;
        let mean_z: f64 =
            traces.iter().map(|t| t.mean_z(1000, 10_000)).sum::<f64>() / traces.len() as f64;
        let std_z: f64 = agg.std[1000..].iter().sum::<f64>() / (agg.std.len() - 1000) as f64;
        let (r1, u1) = AggregateTrace::mean_recovery(&traces, 2000, 10);
        table.row(vec![
            label,
            format!("{mean_z:.2}"),
            format!("{std_z:.2}"),
            match (r1, u1) {
                (Some(v), 0) => format!("{v:.0}"),
                (Some(v), u) => format!("{v:.0} ({u}!)"),
                (None, _) => "never".into(),
            },
            format!("{:.0}", agg.forks_per_run.iter().sum::<usize>() as f64 / agg.runs as f64),
            format!("{:.0}", agg.terms_per_run.iter().sum::<usize>() as f64 / agg.runs as f64),
            format!("{}/{}", agg.extinctions, agg.runs),
        ]);
    }
    println!("ablation_thresholds — DECAFORK+ on Fig.1 failures, {runs} runs\n");
    println!("{}", table.render());
    Ok(())
}
