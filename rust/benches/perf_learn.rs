//! Sharded-vs-shared-stream **trainer** bench (ISSUE 5): RW-SGD on the
//! `learn_10k` workload (10k nodes, 512 model-carrying walks, pure-Rust
//! bigram operator — no artifacts needed), comparing
//!
//! * the shared-stream `Engine` + `TrainerHook` path (the only way to
//!   train before the ShardHook protocol existed), against
//! * the sharded trainer at `DECAFORK_SHARDS_HI` workers (default 8).
//!
//! Before any clock is trusted the bench **hard-asserts the shards = 1
//! loss digest**: the sharded trainer at 1 worker and at the high count
//! must produce bit-identical loss streams and simulation traces — a
//! "speedup" that moved one SGD result would be a bug, not a result.
//! (The shared-stream path is a different trace family — per-walk vs
//! shared randomness — so it is compared on wall-clock only.)
//!
//! Writes `BENCH_learn.json` (or `$DECAFORK_BENCH_OUT`). Bar: sharded
//! ≥ 2× shared-stream steps/s.
//!
//! Env knobs: `DECAFORK_PERF_STEPS` rescales the horizon,
//! `DECAFORK_SHARDS_HI` sets the high worker count,
//! `DECAFORK_PERF_NO_ENFORCE=1` downgrades the 2× gate to a report
//! (2-core hosted runners cannot show an 8-worker win).

mod perf_common;

use std::sync::Arc;

use decafork::learning::{
    presets, train_sharded, ShardedTrainOptions, TrainingRun, TrainingSummary,
};
use perf_common::{assert_bit_identical, enforce_bar, env_u64, steps_per_sec, write_bench_json};
use std::time::Instant;

const SEED: u64 = 0x5EED_1EA4;

fn run_sharded(
    spec: &presets::LearnSpec,
    op: &decafork::learning::BigramOp,
    corpus: &Arc<decafork::learning::ShardedCorpus>,
    workers: usize,
) -> anyhow::Result<(f64, TrainingSummary)> {
    // Every arm is clocked end-to-end including its own engine/graph
    // build (the corpus is shared setup); the shared-stream baseline
    // below is timed the same way, so the ratio compares like with
    // like.
    let t0 = Instant::now();
    let summary = train_sharded(
        &spec.scenario,
        0,
        op,
        Arc::clone(corpus),
        &ShardedTrainOptions {
            workers,
            horizon: spec.scenario.horizon,
            seed: SEED,
            merge_period: spec.merge_period,
        },
    )?;
    let dt = t0.elapsed().as_secs_f64();
    let sps = steps_per_sec(&summary.trace, dt);
    Ok((sps, summary))
}

fn main() -> anyhow::Result<()> {
    let quick_steps = env_u64("DECAFORK_PERF_STEPS").map(|s| s.max(100));
    let workers = env_u64("DECAFORK_SHARDS_HI")
        .map(|v| v as usize)
        .filter(|&s| s >= 2)
        .unwrap_or(8);

    let mut spec = presets::learn_10k();
    // θ̂ floats join the bit-identical oracle (symmetric across worker
    // counts, so the ratios are untouched).
    spec.scenario.params.record_theta = true;
    if let Some(steps) = quick_steps {
        spec.scenario.rescale_to(steps);
    }
    let op = spec.op();
    println!(
        "perf_learn: RW-SGD on {} | {} steps | bigram op {} params, batch {}x{}\n",
        spec.scenario.label(),
        spec.scenario.horizon,
        spec.vocab * spec.vocab,
        spec.batch,
        spec.seq + 1
    );
    let corpus = Arc::new(spec.corpus());

    // Determinism gate first: 1 worker vs the high count, bit-identical
    // loss digest and trace, BEFORE any clock is quoted.
    let (sps_one, sum_one) = run_sharded(&spec, &op, &corpus, 1)?;
    println!("  sharded, 1 worker    : {sps_one:>10.2} steps/s  ({} SGD steps)", sum_one.steps);
    let (sps_hi, sum_hi) = run_sharded(&spec, &op, &corpus, workers)?;
    println!(
        "  sharded, {workers} workers   : {sps_hi:>10.2} steps/s  ({} SGD steps)",
        sum_hi.steps
    );
    assert_bit_identical(
        &sum_one.trace,
        &sum_hi.trace,
        &format!(
            "simulation trace diverged between 1 and {workers} workers — perf numbers meaningless"
        ),
    );
    assert_eq!(
        sum_one.loss_digest(),
        sum_hi.loss_digest(),
        "loss digest diverged between 1 and {workers} workers — perf numbers meaningless"
    );
    println!(
        "  digest check         : OK (0x{:016x}, {} losses, traces bit-identical)",
        sum_one.loss_digest(),
        sum_one.losses.len()
    );

    // Shared-stream baseline: the pre-subsystem way to train. Different
    // trace family (shared randomness), so wall-clock only — timed
    // end-to-end including its engine build, like the sharded arms.
    let t0 = Instant::now();
    let mut engine = spec.scenario.engine(0)?;
    let sum_seq = TrainingRun::execute(
        &mut engine,
        &op,
        Arc::clone(&corpus),
        spec.scenario.horizon,
        SEED,
    )?;
    let dt = t0.elapsed().as_secs_f64();
    let sps_shared = steps_per_sec(&sum_seq.trace, dt);
    println!(
        "  shared-stream engine : {sps_shared:>10.2} steps/s  ({} SGD steps)",
        sum_seq.steps
    );

    let speedup = sps_hi / sps_shared;
    let vs_one = sps_hi / sps_one;
    println!("\n  sharded vs shared-stream : {speedup:>6.2}x  (bar: >= 2.0x)");
    println!("  sharded {workers}w vs 1w        : {vs_one:>6.2}x");

    let pass = speedup >= 2.0;
    let json = format!(
        "{{\n  \"bench\": \"perf_learn\",\n  \"mode\": \"RW-SGD, sharded trainer vs shared-stream trainer, bigram op; shards=1 loss digest asserted bit-identical before clocking\",\n  \"workload\": \"{}\",\n  \"graph\": \"{}\",\n  \"z0\": {},\n  \"steps\": {},\n  \"workers\": {workers},\n  \"loss_digest\": \"0x{:016x}\",\n  \"sgd_steps_sharded\": {},\n  \"sgd_steps_shared_stream\": {},\n  \"steps_per_sec_sharded_1_worker\": {sps_one:.2},\n  \"steps_per_sec_sharded\": {sps_hi:.2},\n  \"steps_per_sec_shared_stream\": {sps_shared:.2},\n  \"sharded_vs_shared_stream\": {speedup:.3},\n  \"sharded_vs_1_worker\": {vs_one:.3},\n  \"acceptance_min_speedup\": 2.0,\n  \"pass\": {pass}\n}}\n",
        spec.name,
        spec.scenario.graph.label(),
        spec.scenario.params.z0,
        spec.scenario.horizon,
        sum_one.loss_digest(),
        sum_hi.steps,
        sum_seq.steps,
    );
    let out = write_bench_json("BENCH_learn.json", &json)?;

    enforce_bar(pass, format!("perf_learn below the 2x sharded-vs-shared-stream bar — see {out}"))
}
