//! Dispatch-overhead bench for the persistent worker pool (ISSUE 4):
//! the stream-mode `ShardedEngine` run **pooled** (workers spawned once,
//! parked between phases) vs **scoped** (the pre-pool behavior: one
//! `std::thread::scope` spawn per chunk per phase) on the same scenario
//! and worker count.
//!
//! Two workloads:
//! * `perf_control_geometric` (1000 nodes, Z0 = 256) — the scale where
//!   per-phase spawning used to make `--shards` *unprofitable*: the
//!   acceptance bar (pooled ≥ 1.5× scoped) and the profitability probe
//!   (pooled multi-worker vs 1-worker inline) both live here;
//! * `scale_100k` — sanity that the pool does not regress the regime
//!   where spawn cost was already noise (reported, not gated).
//!
//! Before any clock is trusted the bench **asserts bit-identical
//! traces** across dispatch modes and worker counts — dispatch decides
//! which thread runs a chunk, never what the chunk computes, so a
//! "speedup" that moved one fork decision would be a bug, not a result.
//!
//! Writes `BENCH_pool.json` (or `$DECAFORK_BENCH_OUT`).
//!
//! Env knobs: `DECAFORK_PERF_STEPS` rescales horizons,
//! `DECAFORK_SHARDS_HI` sets the worker count (default 8),
//! `DECAFORK_PERF_SKIP_100K=1` skips the 100k-node workload (CI smoke:
//! the graph build dominates the budget),
//! `DECAFORK_PERF_NO_ENFORCE=1` downgrades the ≥ 1.5× gate to a report
//! (2-core hosted runners cannot show an 8-worker dispatch win).

mod perf_common;

use decafork::scenario::{presets, Scenario};
use decafork::sim::{DispatchMode, Trace};
use perf_common::{assert_bit_identical, enforce_bar, env_u64, steps_per_sec, write_bench_json};
use std::time::Instant;

fn run_once(
    scenario: &Scenario,
    shards: usize,
    dispatch: DispatchMode,
) -> anyhow::Result<(f64, Trace)> {
    // Clock covers only the stepping: graph build and pool construction
    // are one-time setup (the pool's whole point is that its cost is
    // paid once, not per step).
    let mut e = scenario.sharded_engine_dispatch(0, shards, dispatch)?;
    let t0 = Instant::now();
    e.run_to(scenario.horizon);
    let dt = t0.elapsed().as_secs_f64();
    let trace = e.into_trace();
    Ok((steps_per_sec(&trace, dt), trace))
}

struct Comparison {
    sps_pooled: f64,
    sps_scoped: f64,
    pooled_vs_scoped: f64,
}

fn compare(
    name: &str,
    scenario: &Scenario,
    workers: usize,
) -> anyhow::Result<(Comparison, Trace)> {
    println!("{name}: {} | {} steps | {workers} workers", scenario.label(), scenario.horizon);
    let (sps_pooled, tr_pooled) = run_once(scenario, workers, DispatchMode::Pooled)?;
    println!("  pooled dispatch      : {sps_pooled:>12.1} steps/s");
    let (sps_scoped, tr_scoped) = run_once(scenario, workers, DispatchMode::Scoped)?;
    println!("  scoped dispatch      : {sps_scoped:>12.1} steps/s");
    assert_bit_identical(
        &tr_pooled,
        &tr_scoped,
        &format!(
            "{name}: trace diverged between pooled and scoped dispatch — \
             perf numbers meaningless"
        ),
    );
    let pooled_vs_scoped = sps_pooled / sps_scoped;
    println!("  pooled vs scoped     : {pooled_vs_scoped:>12.2}x");
    println!(
        "  events / final z     : {} / {}",
        tr_pooled.events.len(),
        tr_pooled.z.last().unwrap()
    );
    Ok((Comparison { sps_pooled, sps_scoped, pooled_vs_scoped }, tr_pooled))
}

fn main() -> anyhow::Result<()> {
    let quick_steps = env_u64("DECAFORK_PERF_STEPS").map(|s| s.max(100));
    let workers = env_u64("DECAFORK_SHARDS_HI")
        .map(|v| v as usize)
        .filter(|&s| s >= 2)
        .unwrap_or(8);

    let mut control = presets::perf_control_geometric();
    let mut s100k = presets::scale_100k();
    // θ̂ floats join the bit-identical oracle (symmetric across every
    // dispatch arm, so the ratios are untouched).
    control.params.record_theta = true;
    s100k.params.record_theta = true;
    if let Some(steps) = quick_steps {
        control.rescale_to(steps);
        s100k.rescale_to(steps);
    }

    println!("perf_pool: persistent pool vs per-phase scoped spawning\n");
    let (small, tr_small) = compare("perf_control_geometric", &control, workers)?;
    // Profitability: pooled multi-worker against the zero-thread inline
    // path — the ROADMAP claim this bench exists to check is that with
    // the spawn floor gone, `--shards` pays off at 1000-node scale too.
    let (sps_one, tr_one) = run_once(&control, 1, DispatchMode::Pooled)?;
    assert_bit_identical(
        &tr_one,
        &tr_small,
        &format!("perf_control_geometric: trace diverged between 1 and {workers} workers"),
    );
    let pooled_vs_one = small.sps_pooled / sps_one;
    println!("  1 worker (inline)    : {sps_one:>12.1} steps/s");
    println!("  pooled vs 1 worker   : {pooled_vs_one:>12.2}x  (profitability probe)\n");

    let skip_100k = std::env::var("DECAFORK_PERF_SKIP_100K").is_ok();
    let big = if skip_100k {
        println!("scale_100k: skipped (DECAFORK_PERF_SKIP_100K)");
        None
    } else {
        Some(compare("scale_100k", &s100k, workers)?.0)
    };

    let pass = small.pooled_vs_scoped >= 1.5;
    let fmt_cmp = |c: &Comparison| {
        format!(
            "{{\n    \"steps_per_sec_pooled\": {:.1},\n    \"steps_per_sec_scoped\": {:.1},\n    \"pooled_vs_scoped\": {:.3}\n  }}",
            c.sps_pooled, c.sps_scoped, c.pooled_vs_scoped
        )
    };
    let big_json = match &big {
        Some(c) => fmt_cmp(c),
        None => "null".into(),
    };
    let json = format!(
        "{{\n  \"bench\": \"perf_pool\",\n  \"mode\": \"stream engine, pooled vs scoped dispatch, traces bit-identical\",\n  \"workers\": {workers},\n  \"perf_control_geometric\": {{\n    \"graph\": \"{}\",\n    \"z0\": {},\n    \"steps\": {},\n    \"steps_per_sec_pooled\": {:.1},\n    \"steps_per_sec_scoped\": {:.1},\n    \"steps_per_sec_1_worker\": {sps_one:.1},\n    \"pooled_vs_scoped\": {:.3},\n    \"pooled_vs_1_worker\": {pooled_vs_one:.3}\n  }},\n  \"scale_100k\": {big_json},\n  \"acceptance_min_pooled_vs_scoped\": 1.5,\n  \"pass\": {pass}\n}}\n",
        control.graph.label(),
        control.params.z0,
        control.horizon,
        small.sps_pooled,
        small.sps_scoped,
        small.pooled_vs_scoped,
    );
    let out = write_bench_json("BENCH_pool.json", &json)?;

    enforce_bar(pass, format!("perf_pool below the 1.5x pooled-vs-scoped bar — see {out}"))
}
