//! L3 hot-path throughput: walk-hops/second of the simulation engine on
//! the Fig. 1 workload, plus a scaling sweep. §Perf target:
//! ≥ 10⁷ hops/s single-thread (n=100, Z≈10, empirical survival).

use decafork::control::Decafork;
use decafork::failures::NoFailures;
use decafork::graph::generators;
use decafork::rng::Rng;
use decafork::sim::engine::{Engine, SimParams};
use std::sync::Arc;

fn bench_case(n: usize, d: usize, z0: u32, steps: u64) -> (f64, u64) {
    let g = Arc::new(generators::random_regular(n, d, &mut Rng::new(1)).unwrap());
    let mut e = Engine::new(
        g,
        SimParams { z0, ..Default::default() },
        Box::new(Decafork::new(2.0)),
        Box::new(NoFailures),
        Rng::new(2),
    );
    // Warm: populate node tables.
    e.run_to(steps / 5);
    let hops0 = e.trace().z.iter().map(|&z| z as u64).sum::<u64>();
    let t0 = std::time::Instant::now();
    e.run_to(steps);
    let dt = t0.elapsed();
    let hops = e.trace().z.iter().map(|&z| z as u64).sum::<u64>() - hops0;
    (hops as f64 / dt.as_secs_f64(), hops)
}

fn main() {
    println!("perf_engine: simulation hot-path throughput (single thread)\n");
    println!(
        "{:<28} {:>14} {:>12}",
        "case", "hops/s", "hops"
    );
    for (n, d, z0, steps) in [
        (100usize, 8usize, 10u32, 200_000u64), // Fig.1 workload
        (50, 8, 10, 200_000),
        (200, 8, 10, 200_000),
        (100, 8, 40, 100_000),                 // 4x walk density
        (1000, 8, 10, 100_000),                // big graph
    ] {
        let (rate, hops) = bench_case(n, d, z0, steps);
        println!(
            "{:<28} {:>14.3e} {:>12}",
            format!("n={n} d={d} Z0={z0}"),
            rate,
            hops
        );
    }
}
