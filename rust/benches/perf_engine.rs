//! L3 hot-path throughput: the arena engine vs the frozen seed engine
//! (`ReferenceEngine`) on the ISSUE-1 acceptance workload — 1000-node
//! random-regular graph, 256 walks, 10k steps, ~30% cumulative failures
//! with DECAFORK refilling — plus the historical hops/sec sweep.
//!
//! Writes `BENCH_engine.json` (relative to the bench's working
//! directory — the `rust/` package root under cargo — or to
//! `$DECAFORK_BENCH_OUT`) with steps/sec for both engines and the
//! speedup ratio, so the perf trajectory is recorded run over run.
//! Acceptance bar: `ratio >= 2.0`, recorded in the report's `pass`
//! field but not process-enforced — this bench predates the gate
//! convention and its CI smoke runs without `DECAFORK_PERF_NO_ENFORCE`.
//!
//! Env knobs (shared `perf_common` family): `DECAFORK_PERF_STEPS`
//! overrides the 10k-step horizon (CI smoke uses a smaller value),
//! `DECAFORK_BENCH_OUT` the JSON path.

mod perf_common;

use decafork::control::Decafork;
use decafork::failures::NoFailures;
use decafork::graph::generators;
use decafork::rng::Rng;
use decafork::scenario::presets;
use decafork::sim::engine::{Engine, SimParams};
use std::sync::Arc;
use std::time::Instant;

fn bench_case(n: usize, d: usize, z0: u32, steps: u64) -> (f64, u64) {
    let g = Arc::new(generators::random_regular(n, d, &mut Rng::new(1)).unwrap());
    let mut e = Engine::new(
        g,
        SimParams { z0, ..Default::default() },
        Decafork::new(2.0),
        NoFailures,
        Rng::new(2),
    );
    // Warm: populate node tables.
    e.run_to(steps / 5);
    let hops0 = e.trace().z.iter().map(|&z| z as u64).sum::<u64>();
    let t0 = Instant::now();
    e.run_to(steps);
    let dt = t0.elapsed();
    let hops = e.trace().z.iter().map(|&z| z as u64).sum::<u64>() - hops0;
    (hops as f64 / dt.as_secs_f64(), hops)
}

fn main() -> anyhow::Result<()> {
    // ------------------------------------------------------------------
    // 1. Arena vs reference on the acceptance scenario.
    // ------------------------------------------------------------------
    let mut scenario = presets::perf_hot_loop();
    if let Some(steps) = perf_common::env_u64("DECAFORK_PERF_STEPS") {
        // Proportional shrink via the shared scenario-layer helper:
        // burst times scale with the horizon (floored so t=0 bursts —
        // which never fire, the engine starts at t=1 — cannot appear),
        // the per-hop churn rate stays, so the 30%-cumulative-burst +
        // continuous-churn shape holds at any horizon.
        scenario.rescale_to(steps.max(100));
    }
    let horizon = scenario.horizon;
    println!(
        "perf_engine: {} | n=1000 d=8 Z0=256, {horizon} steps, ~30% cumulative failures",
        scenario.label()
    );

    let t0 = Instant::now();
    let mut reference = scenario.reference_engine(0)?;
    reference.run_to(horizon);
    let dt_ref = t0.elapsed().as_secs_f64();
    let ref_steps_per_s = horizon as f64 / dt_ref;

    let t0 = Instant::now();
    let mut arena = scenario.engine(0)?;
    arena.run_to(horizon);
    let dt_arena = t0.elapsed().as_secs_f64();
    let arena_steps_per_s = horizon as f64 / dt_arena;

    // Sanity: both engines must have simulated the same system.
    assert_eq!(
        arena.trace().z,
        reference.trace().z,
        "arena and reference diverged — perf numbers would be meaningless"
    );

    let ratio = arena_steps_per_s / ref_steps_per_s;
    println!("  reference (seed) : {ref_steps_per_s:>12.1} steps/s  ({dt_ref:.2}s)");
    println!("  arena            : {arena_steps_per_s:>12.1} steps/s  ({dt_arena:.2}s)");
    println!("  speedup          : {ratio:>12.2}x  (acceptance bar: >= 2.0x)");
    println!(
        "  final population : {} walks, {} retired",
        arena.alive(),
        arena.arena().graveyard().len()
    );

    let json = format!(
        "{{\n  \"bench\": \"perf_engine\",\n  \"scenario\": {{\n    \"graph\": \"random-regular n=1000 d=8\",\n    \"z0\": 256,\n    \"steps\": {horizon},\n    \"failures\": \"3 bursts (30% cumulative) + p_f=0.004 churn\"\n  }},\n  \"reference_steps_per_sec\": {ref_steps_per_s:.1},\n  \"arena_steps_per_sec\": {arena_steps_per_s:.1},\n  \"speedup\": {ratio:.3},\n  \"acceptance_min_speedup\": 2.0,\n  \"pass\": {}\n}}\n",
        ratio >= 2.0
    );
    perf_common::write_bench_json("BENCH_engine.json", &json)?;

    // ------------------------------------------------------------------
    // 2. Graph-step sampler micro-bench: precomputed Lemire threshold
    //    (Graph::step) vs the seed's generic nbrs[rng.below(len)] path.
    //    Both consume identical RNG streams (tested in graph::tests);
    //    this records what hoisting the rejection constant buys.
    // ------------------------------------------------------------------
    {
        let g = Arc::new(generators::random_regular(1000, 8, &mut Rng::new(3)).unwrap());
        let hops = 20_000_000u64;
        let mut rng = Rng::new(4);
        let mut pos = 0usize;
        let t0 = Instant::now();
        for _ in 0..hops {
            pos = g.step(pos, &mut rng);
        }
        let strata = hops as f64 / t0.elapsed().as_secs_f64();
        std::hint::black_box(pos);
        let mut rng = Rng::new(4);
        let mut pos = 0usize;
        let t0 = Instant::now();
        for _ in 0..hops {
            let nbrs = g.neighbors(pos);
            pos = nbrs[rng.below(nbrs.len())] as usize;
        }
        let below = hops as f64 / t0.elapsed().as_secs_f64();
        std::hint::black_box(pos);
        println!("\ngraph-step sampler ({hops} hops, n=1000 d=8):");
        println!("  rng.below (seed)   : {below:>12.3e} hops/s");
        println!("  precomputed strata : {strata:>12.3e} hops/s  ({:.2}x)", strata / below);
    }

    // ------------------------------------------------------------------
    // 3. Historical hops/sec sweep (arena engine). §Perf target:
    //    >= 10^7 hops/s single-thread on the Fig. 1 workload.
    // ------------------------------------------------------------------
    println!("\nhops/sec sweep (single thread):");
    println!("{:<28} {:>14} {:>12}", "case", "hops/s", "hops");
    for (n, d, z0, steps) in [
        (100usize, 8usize, 10u32, 200_000u64), // Fig.1 workload
        (50, 8, 10, 200_000),
        (200, 8, 10, 200_000),
        (100, 8, 40, 100_000), // 4x walk density
        (1000, 8, 10, 100_000), // big graph
    ] {
        let (rate, hops) = bench_case(n, d, z0, steps);
        println!("{:<28} {:>14.3e} {:>12}", format!("n={n} d={d} Z0={z0}"), rate, hops);
    }
    Ok(())
}
