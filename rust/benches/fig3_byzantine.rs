//! Bench/regenerator for paper Fig. 3: bursts + Byzantine node with a
//! Byz → No-Byz flip at t = 5000. Only DECAFORK+ handles both phases.

fn main() -> anyhow::Result<()> {
    let runs: usize = std::env::var("DECAFORK_BENCH_RUNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    let t0 = std::time::Instant::now();
    let fig = decafork::figures::fig3(
        runs,
        0,
        decafork::scenario::parse::shards_from_env()?,
        decafork::sim::CoreBudget::from_env()?,
    )?;
    println!("{}", fig.plot(100, 18));
    println!("{}", fig.summary());
    let path = fig.write_csv("results")?;
    println!("fig3 done in {:.2?}; csv {}", t0.elapsed(), path.display());
    Ok(())
}
