//! Bench/regenerator for paper Fig. 4: DECAFORK across n ∈ {50,100,200}
//! (8-regular), per-n tuned ε, bursts at 2000/6000.

fn main() -> anyhow::Result<()> {
    let runs: usize = std::env::var("DECAFORK_BENCH_RUNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    let t0 = std::time::Instant::now();
    let fig = decafork::figures::fig4(
        runs,
        0,
        decafork::scenario::parse::shards_from_env()?,
        decafork::sim::CoreBudget::from_env()?,
    )?;
    println!("{}", fig.plot(100, 18));
    println!("{}", fig.summary());
    let path = fig.write_csv("results")?;
    println!("fig4 done in {:.2?}; csv {}", t0.elapsed(), path.display());
    Ok(())
}
