//! Bench/regenerator for paper Fig. 1: MISSINGPERSON vs DECAFORK vs
//! DECAFORK+ under burst failures. Prints the same series the paper
//! plots (mean Z_t ± std) plus the derived reaction/overshoot rows.
//!
//! `cargo bench --bench fig1_burst` (env DECAFORK_BENCH_RUNS=50 for the
//! paper's replication count).

fn main() -> anyhow::Result<()> {
    let runs: usize = std::env::var("DECAFORK_BENCH_RUNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    let t0 = std::time::Instant::now();
    let fig = decafork::figures::fig1(
        runs,
        0,
        decafork::scenario::parse::shards_from_env()?,
        decafork::sim::CoreBudget::from_env()?,
    )?;
    let dt = t0.elapsed();
    println!("{}", fig.plot(100, 18));
    println!("{}", fig.summary());
    let path = fig.write_csv("results")?;
    println!(
        "fig1: {} curves x {} runs x 10k steps in {:.2?} ({:.1} ms/run-curve); csv {}",
        fig.curves.len(),
        runs,
        dt,
        dt.as_secs_f64() * 1000.0 / (fig.curves.len() * runs) as f64,
        path.display()
    );
    Ok(())
}
