//! Offline API-compatible subset of `rust-lang/libc` (DESIGN.md
//! §Vendored substitutions): just the thread-affinity surface the
//! worker pool's opt-in `--pin-cores` knob needs — `cpu_set_t`,
//! `CPU_ZERO`/`CPU_SET`, and `sched_setaffinity`. The declarations
//! match the real crate's names and shapes, so swapping the registry
//! crate back in is a one-line change in `rust/Cargo.toml`
//! (`libc = "0.2"`).
//!
//! Everything here is Linux-only, exactly like the callers
//! (`runtime::affinity` compiles to a no-op elsewhere): on other
//! targets this crate exports nothing and links nothing.

#![allow(non_camel_case_types)]
// The CPU_* accessors keep the real crate's macro-style names.
#![allow(non_snake_case)]

#[cfg(target_os = "linux")]
mod linux {
    pub type c_int = i32;
    pub type pid_t = i32;
    pub type size_t = usize;

    /// Bits in a `cpu_set_t` (glibc's fixed 1024-CPU mask).
    pub const CPU_SETSIZE: c_int = 1024;

    const ULONG_BITS: usize = 8 * core::mem::size_of::<u64>();

    /// glibc's `cpu_set_t`: 1024 bits as an array of unsigned longs
    /// (`u64` on every 64-bit Linux target this repo builds for).
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct cpu_set_t {
        bits: [u64; CPU_SETSIZE as usize / ULONG_BITS],
    }

    /// Clear every CPU in the set (the `CPU_ZERO` macro).
    ///
    /// # Safety
    /// Matches the real crate's signature (which is `unsafe` for
    /// macro-parity reasons); safe in practice for any valid `&mut`.
    pub unsafe fn CPU_ZERO(set: &mut cpu_set_t) {
        set.bits = [0; CPU_SETSIZE as usize / ULONG_BITS];
    }

    /// Add `cpu` to the set (the `CPU_SET` macro). Out-of-range CPUs
    /// are ignored, as in glibc.
    ///
    /// # Safety
    /// Matches the real crate's signature; safe for any valid `&mut`.
    pub unsafe fn CPU_SET(cpu: usize, set: &mut cpu_set_t) {
        if cpu < CPU_SETSIZE as usize {
            set.bits[cpu / ULONG_BITS] |= 1u64 << (cpu % ULONG_BITS);
        }
    }

    /// Whether `cpu` is in the set (the `CPU_ISSET` macro).
    ///
    /// # Safety
    /// Matches the real crate's signature; safe for any valid `&`.
    pub unsafe fn CPU_ISSET(cpu: usize, set: &cpu_set_t) -> bool {
        cpu < CPU_SETSIZE as usize && set.bits[cpu / ULONG_BITS] & (1u64 << (cpu % ULONG_BITS)) != 0
    }

    extern "C" {
        /// Bind thread `pid` (0 = the calling thread) to the CPUs in
        /// `mask`. Returns 0 on success, -1 on error (e.g. a
        /// cgroup-restricted runner whose cpuset excludes the CPU).
        pub fn sched_setaffinity(pid: pid_t, cpusetsize: size_t, mask: *const cpu_set_t) -> c_int;

        /// Read the calling thread's (or `pid`'s) affinity mask.
        pub fn sched_getaffinity(pid: pid_t, cpusetsize: size_t, mask: *mut cpu_set_t) -> c_int;
    }
}

#[cfg(target_os = "linux")]
pub use linux::*;
