//! Offline stub of the `xla-rs` PJRT bindings.
//!
//! The build environment has neither crates.io access nor a PJRT plugin,
//! so this crate provides just enough of the `xla` API surface for
//! `decafork::runtime` to compile. Every entry point that would touch a
//! real accelerator returns an [`Error`] explaining how to enable the
//! real runtime; nothing in the simulation/control stack depends on it.
//! All runtime-dependent tests, benches and examples gate on
//! `artifacts_present()` and skip before reaching these stubs.
//!
//! To enable real execution, point the `xla` dependency in
//! `rust/Cargo.toml` at the actual bindings; the API below matches the
//! call sites in `decafork::runtime` one-to-one.

use std::fmt;
use std::path::Path;

/// Error type for all stubbed PJRT operations.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} is unavailable: decafork was built with the offline `xla` stub \
         (rust/vendor/xla). Point the `xla` dependency at the real PJRT bindings \
         and run `make artifacts` to enable the learning runtime."
    ))
}

/// Stub of the PJRT CPU client.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PJRT CPU client"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("XLA compilation"))
    }
}

/// Stub of a parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        Err(unavailable("HLO text parsing"))
    }
}

/// Stub of an XLA computation.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Stub of a compiled, loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PJRT execution"))
    }
}

/// Stub of a device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device-to-host transfer"))
    }
}

/// Stub of a host literal.
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("literal reshape"))
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable("tuple destructuring"))
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        Err(unavailable("tuple destructuring"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("literal readback"))
    }
}
