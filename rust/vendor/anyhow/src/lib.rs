//! Offline shim for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this in-tree crate
//! provides the exact subset of the real `anyhow` API the workspace uses:
//! [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros and
//! the [`Context`] extension trait. Error causes are stringified at
//! conversion time (no downcasting); the `{:#}` alternate format prints
//! the full context chain, exactly like the real crate's default report.
//!
//! To switch to the real crate, change the `anyhow` entry in
//! `rust/Cargo.toml` from a `path` dependency to a registry version — no
//! source changes are needed.

use std::fmt;

/// A string-chained error: the head message plus outer context frames,
/// most recent first (matching real `anyhow`'s context ordering).
pub struct Error {
    /// `chain[0]` is the outermost context (or the root message when no
    /// context was attached); later entries are closer to the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Create from a standard error (stringified; the cause chain is
    /// flattened via its `source()` links).
    pub fn new<E: std::error::Error>(error: E) -> Self {
        let mut chain = vec![error.to_string()];
        let mut cause = error.source();
        while let Some(c) = cause {
            chain.push(c.to_string());
            cause = c.source();
        }
        Error { chain }
    }

    /// Wrap with an outer context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole context chain, outermost first.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in &self.chain[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

// Mirrors real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// coherent next to `impl<T> From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context()` / `.with_context()`.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C, F>(self, context: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, context: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(context()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, context: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(context()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an [`Error`] when a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: `", stringify!($cond), "`"));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err()).context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros_format() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(7).unwrap_err()), "unlucky 7");
        let e = anyhow!("plain {}", 1);
        assert_eq!(e.to_string(), "plain 1");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }
}
