//! Quickstart: build a graph, start Z0 random walks under DECAFORK,
//! inject a burst failure, watch the population self-heal.
//!
//!     cargo run --release --example quickstart

use decafork::control::Decafork;
use decafork::failures::Burst;
use decafork::graph::generators;
use decafork::report::ascii_plot;
use decafork::rng::Rng;
use decafork::sim::engine::{Engine, SimParams};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // 1. A communication topology: 100 users, each with 8 neighbors.
    let graph = Arc::new(generators::random_regular(100, 8, &mut Rng::new(7))?);
    println!(
        "graph: n={} m={} mean return time (Kac) = {:.0} steps",
        graph.n(),
        graph.m(),
        graph.mean_return_time(0)
    );

    // 2. Z0 = 10 walks, DECAFORK with the paper's threshold ε = 2
    //    (designable from Irwin–Hall quantiles: see `decafork design`).
    let mut engine = Engine::new(
        graph,
        SimParams::default(), // Z0 = 10, empirical survival, auto warm-up
        Decafork::new(2.0),
        // 3. Failures: 5 walks die at t=2000, 6 more at t=6000 (Fig. 1).
        Burst::paper_default(),
        Rng::new(42),
    );
    println!("control warm-up until t = {}", engine.control_start());

    // 4. Run and inspect.
    engine.run_to(10_000);
    let trace = engine.trace();
    println!(
        "forks: {}  failures: {}  extinct: {}",
        trace.count(decafork::sim::metrics::EventKind::Fork),
        trace.count(decafork::sim::metrics::EventKind::Failure),
        trace.extinct,
    );
    for (i, burst) in [2000u64, 6000].iter().enumerate() {
        match trace.recovery_time(*burst, 10) {
            Some(r) => println!("burst {}: recovered Z0 in {} steps", i + 1, r),
            None => println!("burst {}: NOT recovered", i + 1),
        }
    }
    let z: Vec<f64> = trace.z.iter().map(|&v| v as f64).collect();
    println!("{}", ascii_plot("Z_t (single run)", &[("Z", &z)], 90, 14));
    Ok(())
}
