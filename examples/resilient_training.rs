//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): decentralized learning by
//! random-walk SGD where the walk token carries the model, executed
//! through all three layers — Pallas kernels (L1) inside the JAX train
//! step (L2), AOT-compiled to HLO and driven from the rust walk engine
//! (L3) via PJRT. A burst failure kills model-carrying walks mid-run;
//! DECAFORK forks survivors (copying their models) and training
//! continues. A control arm with no failure-control shows the
//! catastrophic alternative.
//!
//!     make artifacts && cargo run --release --example resilient_training

use decafork::control::{Decafork, NoControl};
use decafork::failures::Burst;
use decafork::graph::generators;
use decafork::learning::{PjrtOp, ShardedCorpus, TrainingRun};
use decafork::report::ascii_plot;
use decafork::rng::Rng;
use decafork::runtime::{artifacts_present, default_artifacts_dir, Runtime, TrainStep};
use decafork::sim::engine::{Engine, SimParams};
use std::sync::Arc;

const N: usize = 32; // nodes
const D: usize = 6; // degree
const Z0: u32 = 4; // model-carrying walks
const HORIZON: u64 = 450; // steps (each visit = 1 SGD step on that walk)
const BURST_T: u64 = 250; // after the auto warm-up (~170 for n=32)
const BURST_KILL: usize = 3;

fn run_arm(
    label: &str,
    control: decafork::control::Control,
    train: &TrainStep,
    corpus: Arc<ShardedCorpus>,
) -> anyhow::Result<decafork::learning::TrainingSummary> {
    let graph = Arc::new(generators::random_regular(N, D, &mut Rng::new(11))?);
    let mut engine = Engine::new(
        graph,
        SimParams { z0: Z0, max_walks: 8, ..Default::default() },
        control,
        Burst::new(vec![(BURST_T, BURST_KILL)]),
        Rng::new(23),
    );
    let t0 = std::time::Instant::now();
    let op = PjrtOp::new(train)?;
    let summary = TrainingRun::execute(&mut engine, &op, corpus, HORIZON, 99)?;
    println!(
        "[{label}] {} SGD steps in {:.1?}; survivors {}; loss {:.3} -> {:.3}",
        summary.steps,
        t0.elapsed(),
        summary.survivors,
        summary.first_loss,
        summary.last_loss_mean
    );
    println!("[{label}] lineage: {}", summary.lineage);
    Ok(summary)
}

fn main() -> anyhow::Result<()> {
    let dir = default_artifacts_dir();
    anyhow::ensure!(
        artifacts_present(&dir),
        "no artifacts at {} — run `make artifacts` first",
        dir.display()
    );
    let rt = Runtime::cpu()?;
    let train = TrainStep::load(&rt, &dir)?;
    println!(
        "model '{}': {} params | batch {} x seq {} | lr {} | vocab {}",
        train.manifest.get("model")?,
        train.param_count()?,
        train.manifest.get_usize("batch")?,
        train.manifest.get_usize("seq")?,
        train.manifest.get_f64("lr")?,
        train.manifest.get_usize("vocab")?,
    );
    let corpus = Arc::new(ShardedCorpus::markov(
        N,
        4096,
        train.manifest.get_usize("vocab")?,
        0xC0FFEE,
    ));
    println!(
        "corpus: {} shards x 4096 tokens, bigram entropy {:.2} nats (uniform would be {:.2})\n",
        N,
        corpus.bigram_entropy(0),
        (train.manifest.get_usize("vocab")? as f64).ln()
    );

    // Resilient arm: DECAFORK replaces the killed walks; the forked
    // copies carry the surviving models' progress. The threshold comes
    // from the Irwin–Hall design rule (Sec. III-B) for Z0 = 4.
    let eps = decafork::stats::irwin_hall::design_epsilon(Z0, 0.02);
    println!("designed DECAFORK threshold for Z0={Z0}: eps = {eps:.2}\n");
    let resilient = run_arm("decafork", Decafork::new(eps).into(), &train, corpus.clone())?;

    // Fragile arm: same failure, no control. (With 3 of 4 walks killed,
    // one walk limps on — kill all Z0 and the task is simply gone.)
    let fragile = run_arm("no-control", NoControl.into(), &train, corpus)?;

    // Report: loss curves (visit order) and population traces.
    let curve = |s: &decafork::learning::TrainingSummary| -> Vec<f64> {
        s.losses
            .chunks(8)
            .map(|c| c.iter().map(|&(_, _, l)| l as f64).sum::<f64>() / c.len() as f64)
            .collect()
    };
    let c1 = curve(&resilient);
    let c2 = curve(&fragile);
    println!(
        "{}",
        ascii_plot(
            "training loss (8-visit means)",
            &[("decafork", &c1), ("no-control", &c2)],
            90,
            14
        )
    );
    let z1: Vec<f64> = resilient.trace.z.iter().map(|&v| v as f64).collect();
    let z2: Vec<f64> = fragile.trace.z.iter().map(|&v| v as f64).collect();
    println!(
        "{}",
        ascii_plot("walk population", &[("decafork", &z1), ("no-control", &z2)], 90, 8)
    );

    // The claims EXPERIMENTS.md records:
    anyhow::ensure!(resilient.last_loss_mean < resilient.first_loss, "no learning progress");
    anyhow::ensure!(resilient.survivors as u32 >= Z0 - 1, "DECAFORK failed to restore redundancy");
    anyhow::ensure!(
        (fragile.survivors as u32) < Z0,
        "control arm should have lost walks permanently"
    );
    println!("resilient_training: OK");
    Ok(())
}
