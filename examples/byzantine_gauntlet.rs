//! Byzantine gauntlet (paper Fig. 3 scenario, sharpened): a node runs a
//! kill-all-arrivals phase, then abruptly turns honest. DECAFORK with a
//! small ε dies in the Byz phase; with a large ε it survives but
//! overshoots after the flip; DECAFORK+ handles both.
//!
//!     cargo run --release --example byzantine_gauntlet

use decafork::report::{ascii_plot, Table};
use decafork::sim::engine::SimParams;
use decafork::sim::{run_many, ControlSpec, ExperimentConfig, FailureSpec, GraphSpec};

fn main() -> anyhow::Result<()> {
    let failures = FailureSpec::Composite(vec![
        FailureSpec::Burst { events: vec![(2000, 5), (6000, 6)] },
        FailureSpec::ByzantineScheduled { node: 1, schedule: vec![(1000, true), (5000, false)] },
    ]);
    let base = ExperimentConfig {
        graph: GraphSpec::RandomRegular { n: 100, d: 8 },
        // DECAFORK_SHARDS>=2 reruns the gauntlet on the stream-mode
        // sharded engine (same system, different sample paths).
        params: SimParams {
            shards: decafork::scenario::parse::shards_from_env()?,
            ..SimParams::default()
        },
        control: ControlSpec::Decafork { epsilon: 2.0 },
        failures,
        horizon: 10_000,
        runs: 10,
        seed: 0xB42,
    };

    let arms = [
        ("decafork e=2.0", ControlSpec::Decafork { epsilon: 2.0 }),
        ("decafork e=3.25", ControlSpec::Decafork { epsilon: 3.25 }),
        ("decafork+ 3.25/5.75", ControlSpec::DecaforkPlus { epsilon: 3.25, epsilon2: 5.75 }),
    ];

    let mut table = Table::new(&[
        "arm",
        "extinct",
        "mean Z [3k,5k] (Byz)",
        "mean Z [5.5k,8k] (post-flip)",
        "max Z post-flip",
    ]);
    let mut series: Vec<(String, Vec<f64>)> = Vec::new();
    for (label, control) in arms {
        let cfg = ExperimentConfig { control, ..base.clone() };
        let (traces, agg) = run_many(&cfg, 0)?;
        let byz_mean: f64 =
            traces.iter().map(|t| t.mean_z(3000, 5000)).sum::<f64>() / traces.len() as f64;
        let post_mean: f64 =
            traces.iter().map(|t| t.mean_z(5500, 8000)).sum::<f64>() / traces.len() as f64;
        let post_max = traces.iter().map(|t| t.max_z(5000, 8000)).max().unwrap();
        table.row(vec![
            label.to_string(),
            format!("{}/{}", agg.extinctions, agg.runs),
            format!("{byz_mean:.1}"),
            format!("{post_mean:.1}"),
            format!("{post_max}"),
        ]);
        series.push((label.to_string(), agg.mean));
    }
    let plot_series: Vec<(&str, &[f64])> =
        series.iter().map(|(l, v)| (l.as_str(), v.as_slice())).collect();
    println!(
        "{}",
        ascii_plot("Byzantine gauntlet: Byz until t=5000, honest after", &plot_series, 100, 16)
    );
    println!("{}", table.render());
    println!("expected shape (paper Fig. 3): only DECAFORK+ both survives Byz and avoids the post-flip overshoot.");
    Ok(())
}
