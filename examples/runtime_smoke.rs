fn main() -> anyhow::Result<()> {
    let rt = decafork::runtime::Runtime::cpu()?;
    let dir = std::path::Path::new("artifacts");
    let ts = decafork::runtime::TrainStep::load(&rt, dir)?;
    let pc = ts.param_count()?;
    println!("params {pc}");
    let params: Vec<f32> = {
        let bytes = std::fs::read(dir.join("init_params.f32"))?;
        bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0],c[1],c[2],c[3]])).collect()
    };
    assert_eq!(params.len(), pc);
    let (b, t1) = ts.token_shape()?;
    let tokens: Vec<i32> = (0..b*t1).map(|i| (i % 31) as i32).collect();
    let t0 = std::time::Instant::now();
    let (mut p, l0) = ts.step(&params, &tokens)?;
    let mut l = l0;
    for _ in 0..9 { let (np, nl) = ts.step(&p, &tokens)?; p = np; l = nl; }
    println!("loss {l0} -> {l} ({:?}/step)", t0.elapsed()/10);
    assert!(l < l0);
    let th = decafork::runtime::ThetaKernel::load(&rt, dir)?;
    let n = th.nodes; let k = th.walks;
    let elapsed = vec![10.0f32; n*k];
    let q = vec![0.02f32; n];
    let mask = vec![1.0f32; n*k];
    let theta = th.theta(&elapsed, &q, &mask)?;
    let expect = 0.5 + k as f32 * (1.0f32-0.02).powi(10);
    println!("theta[0] = {} expect {}", theta[0], expect);
    assert!((theta[0]-expect).abs() < 0.01);
    println!("runtime smoke OK");
    Ok(())
}
