//! Topology sweep (paper Fig. 6 extended): DECAFORK across graph
//! families, reporting per-family recovery statistics and the return-time
//! scale that drives them. Shows the algorithm needs no per-topology
//! retuning because each node estimates its own return-time distribution.
//!
//!     cargo run --release --example topology_sweep

use decafork::graph::properties;
use decafork::report::Table;
use decafork::rng::Rng;
use decafork::sim::engine::SimParams;
use decafork::sim::{run_many, AggregateTrace, ControlSpec, ExperimentConfig, FailureSpec, GraphSpec};

fn main() -> anyhow::Result<()> {
    let families: Vec<(&str, GraphSpec, f64)> = vec![
        ("8-regular", GraphSpec::RandomRegular { n: 100, d: 8 }, 2.0),
        ("complete", GraphSpec::Complete { n: 100 }, 2.0),
        ("erdos-renyi p=.08", GraphSpec::ErdosRenyi { n: 100, p: 0.08 }, 1.9),
        ("power-law m=4", GraphSpec::PowerLaw { n: 100, m: 4 }, 2.1),
        ("torus 10x10", GraphSpec::Torus { w: 10, h: 10 }, 2.0),
        ("ring", GraphSpec::Ring { n: 100 }, 2.0),
    ];

    let mut table = Table::new(&[
        "family",
        "diam",
        "Kac E[R]",
        "extinct",
        "mean Z",
        "reaction b1",
        "reaction b2",
        "forks/run",
    ]);

    for (label, graph, eps) in families {
        let mut grng = Rng::new(1);
        let g = graph.build(&mut grng)?;
        let diam = properties::diameter(&g);
        let kac = g.mean_return_time(0);

        let cfg = ExperimentConfig {
            graph: graph.clone(),
            params: SimParams {
                shards: decafork::scenario::parse::shards_from_env()?,
                ..Default::default()
            },
            control: ControlSpec::Decafork { epsilon: eps },
            failures: FailureSpec::paper_bursts(),
            horizon: 10_000,
            runs: 10,
            seed: 0x70B0,
        };
        let (traces, agg) = run_many(&cfg, 0)?;
        let (r1, u1) = AggregateTrace::mean_recovery(&traces, 2000, 10);
        let (r2, u2) = AggregateTrace::mean_recovery(&traces, 6000, 10);
        let fmt_r = |r: Option<f64>, u: usize| match r {
            Some(v) if u == 0 => format!("{v:.0}"),
            Some(v) => format!("{v:.0} ({u}!)"),
            None => "never".into(),
        };
        let mean_z: f64 =
            traces.iter().map(|t| t.mean_z(1000, 10_000)).sum::<f64>() / traces.len() as f64;
        table.row(vec![
            label.to_string(),
            diam.to_string(),
            format!("{kac:.0}"),
            format!("{}/{}", agg.extinctions, agg.runs),
            format!("{mean_z:.1}"),
            fmt_r(r1, u1),
            fmt_r(r2, u2),
            format!("{:.1}", agg.forks_per_run.iter().sum::<usize>() as f64 / agg.runs as f64),
        ]);
    }
    println!("{}", table.render());
    println!("note: the ring's huge return times (E[R] = n) slow both estimation and recovery —");
    println!("the paper's families are all low-diameter, where DECAFORK reacts within a few hundred steps.");
    Ok(())
}
